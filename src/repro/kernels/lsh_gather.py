"""Pallas TPU kernel: fused LSH bucket-gather + multiprobe dedup.

Device-side LSH probing (core/probe.py, DESIGN.md §11) turns probe
bucket ids into candidate ids by indexing the member tables:
``cand[q, t, j] = tables[t, pb[q, t, j]]``.  As a plain XLA gather this
materializes the full ``[q, l, n_probes]`` index tensor in HBM and
re-reads the (multi-MB) member table per probe.  This kernel keeps one
table's ``[B, cap]`` bucket matrix resident in VMEM for a whole grid
column and emits the candidate block directly.

Fused multiprobe **dedup**: `_lsh_multiprobe` pads its probe schedule by
repeating the identity probe, so duplicate bucket ids within one
(query, table) pair are common — every duplicate block is pure wasted
verify bandwidth.  Probe j whose bucket id equals an earlier probe
j' < j of the same pair emits an all ``-1`` block instead (``-1`` is the
existing empty-slot sentinel), which preserves the candidate *set* and
the verified counts exactly (verification already sort-dedups ids and
masks ``-1``) while letting the verify stage skip the repeats.

TPU formulation (no gather primitive inside Pallas kernels):
  * grid ``(q_blocks, l)`` — per step the probe block ``[Bq, n_probes]``
    and ONE table ``[B, cap]`` are VMEM-resident.
  * the row gather is a one-hot MXU matmul: ``onehot[Bq, B] @ table[B,
    cap]``.  int32 ids are split into 16-bit halves gathered as f32
    (both halves < 2**16 are exact in f32; products are value*1.0 or
    value*0.0 and adding zeros is exact), then recombined in int32 — the
    result is bit-identical to a direct gather for every int32 id, not
    just ids below the f32 24-bit window.
  * dedup masks are plain VPU compares against the earlier probes of the
    same block (the schedule length ``n_probes`` is static and small).

VMEM budget: table ``B*cap`` int32 plus its two f32 half tables (3x) and
the ``[Bq, B]`` f32 one-hot.  At the default ``block_q=128`` with
B=8192, cap=16: 8192*16*4*3 = 1.5 MB + 128*8192*4 = 4 MB, comfortably
inside the ~16 MB budget; the kernel engages when one table's buckets
fit VMEM (the replicated-probe regime — exactly where the XLA gather
was the bottleneck).

The jnp path (`lsh_bucket_gather_jnp`) is the reference formulation:
direct advanced-indexing gather + the same dedup mask.  Both paths
consume and produce only integers, so they are bit-identical by
construction — the device-probe parity tests compare them exactly.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.range_count import default_interpret


def lsh_probe_dup_mask(pb: jax.Array) -> jax.Array:
    """bool [..., n_probes]: True where the probe's bucket id equals an
    EARLIER probe of the same (query, table) pair — the blocks the fused
    gather replaces with ``-1``.  Shared by the jnp path, the kernel
    (loop form of the same compares), and the tests."""
    n_probes = pb.shape[-1]
    eq = pb[..., :, None] == pb[..., None, :]
    earlier = jnp.tril(jnp.ones((n_probes, n_probes), bool), k=-1)
    return jnp.any(eq & earlier, axis=-1)


def lsh_bucket_gather_jnp(tables: jax.Array, pb: jax.Array) -> jax.Array:
    """Reference formulation: XLA gather + dedup mask.

    tables int32 [l, B, cap] (-1 padded buckets), pb int32
    [q, l, n_probes] probe bucket ids.  Returns int32
    [q, l*n_probes*cap] candidate ids, duplicate probes blanked to -1.
    """
    q = pb.shape[0]
    cand = tables[jnp.arange(tables.shape[0])[None, :, None], pb]
    dup = lsh_probe_dup_mask(pb)
    cand = jnp.where(dup[..., None], jnp.int32(-1), cand)
    return cand.reshape(q, -1)


def _kernel(pb_ref, lo_ref, hi_ref, out_ref, *, n_probes: int, cap: int):
    pb = pb_ref[:, 0, :]                          # [Bq, n_probes] int32
    lo = lo_ref[0]                                # [B, cap] f32 (id+1 & 0xffff)
    hi = hi_ref[0]                                # [B, cap] f32 (id+1 >> 16)
    bq = pb.shape[0]
    nb = lo.shape[0]
    iota_b = jax.lax.broadcasted_iota(jnp.int32, (bq, nb), 1)
    for j in range(n_probes):
        onehot = (iota_b == pb[:, j][:, None]).astype(jnp.float32)
        g_lo = jax.lax.dot_general(onehot, lo, (((1,), (0,)), ((), ())),
                                   preferred_element_type=jnp.float32)
        g_hi = jax.lax.dot_general(onehot, hi, (((1,), (0,)), ((), ())),
                                   preferred_element_type=jnp.float32)
        blk = ((g_hi.astype(jnp.int32) << 16)
               | g_lo.astype(jnp.int32)) - 1     # undo the +1 shift
        dup = jnp.zeros((bq,), bool)
        for jp in range(j):
            dup = dup | (pb[:, j] == pb[:, jp])
        blk = jnp.where(dup[:, None], jnp.int32(-1), blk)
        out_ref[:, 0, j * cap:(j + 1) * cap] = blk


@functools.partial(jax.jit, static_argnames=("block_q", "interpret"))
def lsh_bucket_gather_pallas(tables: jax.Array, pb: jax.Array, *,
                             block_q: int = 128,
                             interpret: bool | None = None) -> jax.Array:
    """Padded-shape kernel entry: pb rows must be a block_q multiple
    (padding handled by ops.lsh_bucket_gather).  Same contract as
    `lsh_bucket_gather_jnp`, bit-identical output.  `interpret=None`
    derives the mode from the runtime platform (compiled on TPU,
    interpret elsewhere)."""
    if interpret is None:
        interpret = default_interpret()
    q, l, n_probes = pb.shape
    _, nb, cap = tables.shape
    assert q % block_q == 0
    shifted = tables.astype(jnp.int32) + 1       # ids >= -1 -> values >= 0
    lo = (shifted & 0xFFFF).astype(jnp.float32)
    hi = (shifted >> 16).astype(jnp.float32)

    kernel = functools.partial(_kernel, n_probes=n_probes, cap=cap)
    out = pl.pallas_call(
        kernel,
        grid=(q // block_q, l),
        in_specs=[
            pl.BlockSpec((block_q, 1, n_probes), lambda i, t: (i, t, 0)),
            pl.BlockSpec((1, nb, cap), lambda i, t: (t, 0, 0)),
            pl.BlockSpec((1, nb, cap), lambda i, t: (t, 0, 0)),
        ],
        out_specs=pl.BlockSpec((block_q, 1, n_probes * cap),
                               lambda i, t: (i, t, 0)),
        out_shape=jax.ShapeDtypeStruct((q, l, n_probes * cap), jnp.int32),
        interpret=interpret,
    )(pb, lo, hi)
    return out.reshape(q, -1)
