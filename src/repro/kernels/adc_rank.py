"""Pallas TPU kernel: flash-style fused IVF-PQ ADC ranking.

The IVF-PQ device probe (core/probe.py `_ivfpq_block`) used to rank the
candidate pool through a generic XLA chain: build the full ``[b, m,
256]`` LUT tensor, ``transpose`` it, gather per-candidate codes with
``take_along_axis``, reduce over segments, then ``top_k`` — four HBM
round-trips over intermediates larger than the inputs.  This kernel
fuses the whole pipeline into one VMEM residency per query tile, in the
spirit of flash attention's "never materialize the big intermediate":

  1. **LUT build** — per PQ segment ``mi`` the ``[Bb, 256]`` distance
     table ``|q_mi|^2 - 2 q_mi . c + |c|^2`` is one MXU matmul against
     the VMEM-resident codebook slice (`lut_segment`, shared verbatim
     with the jnp path).
  2. **code gather** — the candidates' PQ code rows stream out of the
     VMEM-resident ``[n, m]`` code table via a ``fori_loop`` of dynamic
     row slices (no gather primitive inside Pallas kernels).
  3. **accumulate** — the per-segment LUT lookup is a one-hot matmul
     over the 256 codewords (exact: products are value*1.0/value*0.0
     and adding zeros is exact), accumulated in ascending segment order.
  4. **streaming top-k** — ``n_cand`` rounds of masked argmin selection
     with first-index tie-breaking, which reproduces `jax.lax.top_k`'s
     documented tie order exactly (lower index first).

Bit-identity contract: the jnp path (`adc_rank_jnp`) accumulates the
same per-segment lookups in the same ascending order and selects with
`jax.lax.top_k`, so pallas and jnp candidate ids are bit-identical by
construction — including inf ties from ``-1`` padding lanes and
duplicate ids from overlapping inverted lists.  The pre-existing
transpose+take_along_axis+top_k chain survives as `adc_rank_chain` (the
ops-level "ref" backend and the benchmark baseline); its segment
reduction order is whatever XLA picks for ``.sum()``, so it is
value-identical but not guaranteed bit-identical on ties.

VMEM budget at the default ``block_b=8`` (C = n_probe*cap candidates,
typically <= 512; m <= 16 segments; codes n*m uint8): codebooks
m*256*seg f32 <= 1 MB, code table <= a few MB for bench-scale n, onehot
``[Bb, C, 256]`` f32 = 8*512*256*4 = 4 MB, LUT slice 8*256*4 = 8 KB —
inside the ~16 MB budget.  The kernel engages when the code table fits
VMEM (the replicated-probe regime).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.range_count import default_interpret


def lut_segment(q_mi: jax.Array, cb_mi: jax.Array) -> jax.Array:
    """f32 [b, 256] ADC table for ONE PQ segment: ``|q|^2 - 2 q.c +
    |c|^2`` with q_mi [b, seg], cb_mi [256, seg].  The single source of
    truth for both the jnp path and the kernel body — identical
    primitive sequence means identical bits."""
    dots = jax.lax.dot_general(q_mi, cb_mi, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.float32)
    return (jnp.sum(q_mi * q_mi, -1)[:, None] - 2.0 * dots
            + jnp.sum(cb_mi * cb_mi, -1)[None, :])


def adc_rank_chain(q: jax.Array, codebooks: jax.Array, cand: jax.Array,
                   codes: jax.Array, *, n_cand: int) -> jax.Array:
    """The pre-kernel XLA chain (benchmark baseline / ops-level "ref"):
    full-LUT einsum, transpose, take_along_axis, sum, top_k.

    q f32 [b, dim], codebooks f32 [m, 256, seg], cand int32 [b, C]
    (-1 padded), codes uint8 [n, m].  Returns int32 [b, n_cand].
    """
    b = q.shape[0]
    m, _, seg = codebooks.shape
    qseg = q.reshape(b, m, seg)
    tables = (jnp.sum(qseg * qseg, -1)[:, :, None]
              - 2.0 * jnp.einsum("bms,mcs->bmc", qseg, codebooks)
              + jnp.sum(codebooks * codebooks, -1)[None])
    code_blk = codes[jnp.maximum(cand, 0)].astype(jnp.int32)
    adc = jnp.take_along_axis(jnp.transpose(tables, (0, 2, 1)),
                              code_blk, axis=1).sum(axis=2)
    adc = jnp.where(cand < 0, jnp.inf, adc)
    _, top = jax.lax.top_k(-adc, n_cand)
    return jnp.take_along_axis(cand, top, axis=1)


def adc_rank_jnp(q: jax.Array, codebooks: jax.Array, cand: jax.Array,
                 codes: jax.Array, *, n_cand: int) -> jax.Array:
    """Flat-LUT formulation: per-segment ``[b, 256]`` tables looked up
    and accumulated in ascending segment order — no ``[b, m, 256]``
    tensor, no transpose, no ``[b, C, m]`` gather intermediate.  Same
    contract as `adc_rank_chain`; bit-identical to the pallas kernel by
    construction (see module docstring)."""
    b = q.shape[0]
    m, _, seg = codebooks.shape
    qseg = q.reshape(b, m, seg)
    code_blk = codes[jnp.maximum(cand, 0)].astype(jnp.int32)   # [b, C, m]
    adc = jnp.zeros(cand.shape, jnp.float32)
    for mi in range(m):
        lut = lut_segment(qseg[:, mi], codebooks[mi])           # [b, 256]
        adc = adc + jnp.take_along_axis(lut, code_blk[:, :, mi], axis=1)
    adc = jnp.where(cand < 0, jnp.inf, adc)
    _, top = jax.lax.top_k(-adc, n_cand)
    return jnp.take_along_axis(cand, top, axis=1)


def _kernel(q_ref, cb_ref, cand_ref, codes_ref, out_ref, *, n_cand: int):
    qseg = q_ref[...].astype(jnp.float32)         # [Bb, m, seg]
    cbs = cb_ref[...].astype(jnp.float32)         # [m, 256, seg]
    cand = cand_ref[...]                          # [Bb, C] int32
    codes = codes_ref[...].astype(jnp.int32)      # [n, m]
    bb, c = cand.shape
    m = qseg.shape[1]
    safe = jnp.maximum(cand, 0)

    # (2) fused code gather: candidate rows stream out of the resident
    # code table one dynamic row slice per (query, candidate) lane
    def gather(t, acc):
        bi, ci = t // c, t % c
        row = jax.lax.dynamic_slice(codes, (safe[bi, ci], 0), (1, m))
        return jax.lax.dynamic_update_slice(acc, row[None], (bi, ci, 0))

    code_blk = jax.lax.fori_loop(0, bb * c, gather,
                                 jnp.zeros((bb, c, m), jnp.int32))

    # (1)+(3) per-segment LUT build + one-hot accumulate, ascending mi
    adc = jnp.zeros((bb, c), jnp.float32)
    for mi in range(m):
        lut = lut_segment(qseg[:, mi], cbs[mi])                 # [Bb, 256]
        onehot = (code_blk[:, :, mi][:, :, None]
                  == jax.lax.broadcasted_iota(jnp.int32, (bb, c, 256), 2)
                  ).astype(jnp.float32)
        adc = adc + jax.lax.dot_general(
            onehot, lut, (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32)
    adc = jnp.where(cand < 0, jnp.inf, adc)

    # (4) streaming top-k: n_cand masked argmin rounds; first-index
    # tie-break among NOT-yet-taken lanes == lax.top_k's stable order
    def select(k, st):
        taken, out_ids = st
        masked = jnp.where(taken, jnp.inf, adc)
        v = jnp.min(masked, axis=1, keepdims=True)
        pick = (masked == v) & ~taken
        j = jnp.argmax(pick, axis=1)                            # [Bb]
        ids = jnp.take_along_axis(cand, j[:, None], axis=1)
        out_ids = jax.lax.dynamic_update_slice(out_ids, ids, (0, k))
        taken = taken | (jax.lax.broadcasted_iota(jnp.int32, (bb, c), 1)
                         == j[:, None])
        return taken, out_ids

    _, out_ids = jax.lax.fori_loop(
        0, n_cand, select,
        (jnp.zeros((bb, c), bool), jnp.zeros((bb, n_cand), jnp.int32)))
    out_ref[...] = out_ids


@functools.partial(jax.jit, static_argnames=("n_cand", "block_b", "interpret"))
def adc_rank_pallas(q: jax.Array, codebooks: jax.Array, cand: jax.Array,
                    codes: jax.Array, *, n_cand: int, block_b: int = 8,
                    interpret: bool | None = None) -> jax.Array:
    """Padded-shape kernel entry: q rows must be a block_b multiple
    (padding handled by ops.adc_rank).  Same contract as `adc_rank_jnp`,
    bit-identical output.  `interpret=None` derives the mode from the
    runtime platform (compiled on TPU, interpret elsewhere)."""
    if interpret is None:
        interpret = default_interpret()
    b, dim = q.shape
    m, _, seg = codebooks.shape
    n = codes.shape[0]
    c = cand.shape[1]
    assert b % block_b == 0 and m * seg == dim and n_cand <= c

    kernel = functools.partial(_kernel, n_cand=n_cand)
    return pl.pallas_call(
        kernel,
        grid=(b // block_b,),
        in_specs=[
            pl.BlockSpec((block_b, m, seg), lambda i: (i, 0, 0)),
            pl.BlockSpec((m, 256, seg), lambda i: (0, 0, 0)),
            pl.BlockSpec((block_b, c), lambda i: (i, 0)),
            pl.BlockSpec((n, m), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, n_cand), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, n_cand), jnp.int32),
        interpret=interpret,
    )(q.reshape(b, m, seg), codebooks, cand, codes)
