"""Public jit'd wrappers around the Pallas kernels.

Backend selection:
  * "pallas"  — the TPU kernel (interpret=True automatically on CPU, which
    executes the kernel body in Python for correctness validation).
  * "jnp"     — a blocked pure-jnp path (fast on this CPU container; same
    math, compiled by XLA:CPU). Used as the default off-TPU so benchmarks
    are not bottlenecked by interpret-mode overhead.
  * "auto"    — pallas on TPU, jnp elsewhere.

All padding/unpadding (row blocks, eps-chunk multiples, feature-dim
alignment) is handled here so kernels only ever see aligned shapes.
Kernel `interpret=` mode is derived from the runtime platform at these
call sites (`range_count.default_interpret`: compiled on TPU, interpret
elsewhere) — a TPU run can never silently interpret.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.kernels.adc_rank import (adc_rank_chain, adc_rank_jnp,
                                    adc_rank_pallas)
from repro.kernels.fused_mlp import mlp_forward_pallas
from repro.kernels.lsh_gather import (lsh_bucket_gather_jnp,
                                      lsh_bucket_gather_pallas)
from repro.kernels.range_count import range_count_hist_pallas


def _resolve(backend: str) -> str:
    if backend == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "jnp"
    return backend


def _pad_rows(x: jax.Array, mult: int) -> jax.Array:
    n = x.shape[0]
    pad = (-n) % mult
    if pad == 0:
        return x
    return jnp.concatenate([x, jnp.zeros((pad,) + x.shape[1:], x.dtype)], axis=0)


def blocked_hist(q, r, eps_grid, *, metric: str, block_r: int, nr_valid: int):
    """Traceable lax.scan over R blocks: O(block) memory, XLA-fused
    compare+reduce. r.shape[0] must be a block_r multiple. This is the
    per-shard compute of the engine's sharded sweep (core/engine.py) —
    keep it jit-free so it composes under shard_map / outer jits."""
    nr = r.shape[0]
    nblk = nr // block_r
    rb = r.reshape(nblk, block_r, r.shape[1])
    eps = eps_grid.astype(jnp.float32)
    qf = q.astype(jnp.float32)

    def body(carry, xs):
        blk, base = xs
        dots = qf @ blk.astype(jnp.float32).T
        if metric == "cosine":
            d = 1.0 - dots
        else:
            d = jnp.sqrt(jnp.maximum(2.0 - 2.0 * dots, 0.0))
        valid = (base + jnp.arange(block_r)) < nr_valid
        d = jnp.where(valid[None, :], d, jnp.inf)
        cnt = jnp.sum(d[:, :, None] <= eps[None, None, :], axis=1, dtype=jnp.int32)
        return carry + cnt, None

    init = jnp.zeros((q.shape[0], eps.shape[0]), jnp.int32)
    bases = jnp.arange(nblk) * block_r
    out, _ = jax.lax.scan(body, init, (rb, bases))
    return out


_jnp_blocked_hist = functools.partial(
    jax.jit, static_argnames=("metric", "block_r", "nr_valid"))(blocked_hist)


def range_count_hist(q, r, eps_grid, *, metric: str = "cosine",
                     backend: str = "auto", block_q: int = 256,
                     block_r: int = 512, eps_chunk: int = 8) -> jax.Array:
    """counts[i, j] = #-neighbors of q[i] in r within eps_grid[j]. int32 [nq, m].

    Handles arbitrary nq/nr/m by padding; eps_grid must be sorted ascending.
    """
    q = jnp.asarray(q)
    r = jnp.asarray(r)
    eps_grid = jnp.asarray(eps_grid, jnp.float32)
    nq, m = q.shape[0], eps_grid.shape[0]
    nr = r.shape[0]
    be = _resolve(backend)

    if be == "ref":
        return ref.range_count_hist(q, r, eps_grid, metric)

    if be == "jnp":
        rp = _pad_rows(r, block_r)
        out = _jnp_blocked_hist(q, rp, eps_grid, metric=metric,
                                block_r=block_r, nr_valid=nr)
        return out

    if be == "pallas":
        qp = _pad_rows(q, block_q)
        rp = _pad_rows(r, block_r)
        mp = (-m) % eps_chunk
        # pad eps grid with +inf-like large values, slice the extra cols off
        egp = jnp.concatenate([eps_grid, jnp.full((mp,), jnp.inf, jnp.float32)])
        out = range_count_hist_pallas(qp, rp, egp, metric=metric, nr_valid=nr,
                                      block_q=block_q, block_r=block_r,
                                      eps_chunk=eps_chunk, interpret=None)
        return out[:nq, :m]

    raise ValueError(f"unknown backend {be!r}")


def range_count(q, r, eps: float, *, metric: str = "cosine",
                backend: str = "auto", **kw) -> jax.Array:
    """Neighbor count within a single eps. int32 [nq]."""
    return range_count_hist(q, r, jnp.asarray([eps], jnp.float32),
                            metric=metric, backend=backend, **kw)[:, 0]


def mlp_forward(params, x, *, backend: str = "auto", block_n: int = 256) -> jax.Array:
    """Fused estimator inference. params: tuple of (w, b [1,dout]) pairs."""
    x = jnp.asarray(x)
    be = _resolve(backend)
    if be in ("jnp", "ref"):
        return ref.mlp_forward(params, x)
    n = x.shape[0]
    xp = _pad_rows(x, block_n)
    out = mlp_forward_pallas(tuple((jnp.asarray(w), jnp.asarray(b)) for w, b in params),
                             xp, block_n=block_n, interpret=None)
    return out[:n]


def lsh_bucket_gather(tables, pb, *, backend: str = "auto",
                      block_q: int = 128) -> jax.Array:
    """LSH member-table gather + multiprobe dedup (kernels/lsh_gather.py).

    tables int32 [l, B, cap], pb int32 [q, l, n_probes].  Returns int32
    [q, l*n_probes*cap] candidate ids; duplicate probe blocks blanked to
    -1.  All backends are bit-identical by construction (integer-only);
    "ref"/"jnp" take the direct-gather formulation, "pallas" the fused
    one-hot kernel (interpret mode derived from the platform).  Safe to
    call inside jitted programs — padding here is traceable."""
    be = _resolve(backend)
    if be in ("jnp", "ref"):
        return lsh_bucket_gather_jnp(tables, pb)
    if be == "pallas":
        nq = pb.shape[0]
        pbp = _pad_rows(pb, block_q)
        return lsh_bucket_gather_pallas(tables, pbp, block_q=block_q)[:nq]
    raise ValueError(f"unknown backend {be!r}")


def adc_rank(q, codebooks, cand, codes, *, n_cand: int,
             backend: str = "auto", block_b: int = 8) -> jax.Array:
    """IVF-PQ ADC candidate ranking (kernels/adc_rank.py).

    q f32 [b, dim], codebooks f32 [m, 256, seg], cand int32 [b, C]
    (-1 padded), codes uint8 [n, m].  Returns the n_cand best candidate
    ids int32 [b, n_cand].  "jnp" (flat per-segment LUT accumulate) and
    "pallas" (fused kernel) are bit-identical by construction; "ref" is
    the pre-kernel transpose+take_along_axis+top_k chain (value-
    identical, tie order unspecified) kept as baseline/oracle.  Safe to
    call inside jitted programs."""
    be = _resolve(backend)
    if be == "ref":
        return adc_rank_chain(q, codebooks, cand, codes, n_cand=n_cand)
    if be == "jnp":
        return adc_rank_jnp(q, codebooks, cand, codes, n_cand=n_cand)
    if be == "pallas":
        b = q.shape[0]
        qp = _pad_rows(q, block_b)
        cp = jnp.concatenate(
            [cand, jnp.full((qp.shape[0] - b,) + cand.shape[1:], -1,
                            cand.dtype)], axis=0)
        return adc_rank_pallas(qp, codebooks, cp, codes, n_cand=n_cand,
                               block_b=block_b)[:b]
    raise ValueError(f"unknown backend {be!r}")
