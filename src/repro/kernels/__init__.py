# Pallas TPU kernels for the paper's compute hot-spots:
#   range_count.py — fused tiled pairwise-distance + eps-histogram
#                    (ground-truth targets + join verification)
#   fused_mlp.py   — VMEM-resident estimator inference
#   lsh_gather.py  — fused LSH bucket-gather + multiprobe dedup
#                    (device probing, DESIGN.md §15)
#   adc_rank.py    — flash-style fused IVF-PQ ADC ranking (LUT build +
#                    code gather + accumulate + streaming top-k)
# ops.py holds the jit'd public wrappers (incl. the platform-derived
# interpret= policy); ref.py the pure-jnp oracles.
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
