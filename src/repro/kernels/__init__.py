# Pallas TPU kernels for the paper's compute hot-spots:
#   range_count.py — fused tiled pairwise-distance + eps-histogram
#                    (ground-truth targets + join verification)
#   fused_mlp.py   — VMEM-resident estimator inference
# ops.py holds the jit'd public wrappers; ref.py the pure-jnp oracles.
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
