"""Pure-jnp oracles for every Pallas kernel in this package.

These are the correctness references the kernel tests sweep against
(shapes x dtypes, assert_allclose). They are deliberately unblocked and
simple — clarity over speed.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def pair_distances(q: jax.Array, r: jax.Array, metric: str) -> jax.Array:
    """Distances between unit-normalized rows of q [nq,d] and r [nr,d]."""
    dots = jnp.einsum("qd,rd->qr", q.astype(jnp.float32), r.astype(jnp.float32))
    if metric == "cosine":
        return 1.0 - dots
    if metric == "l2":
        return jnp.sqrt(jnp.maximum(2.0 - 2.0 * dots, 0.0))
    raise ValueError(f"unknown metric {metric!r}")


def range_count_hist(q: jax.Array, r: jax.Array, eps_grid: jax.Array,
                     metric: str = "cosine") -> jax.Array:
    """counts[i, j] = #{rows r_k of r : d(q_i, r_k) <= eps_grid[j]}.  int32 [nq, m].

    eps_grid must be sorted ascending. Oracle for kernels/range_count.py.
    """
    d = pair_distances(q, r, metric)                       # [nq, nr]
    cmp = d[:, :, None] <= eps_grid[None, None, :].astype(jnp.float32)
    return jnp.sum(cmp, axis=1, dtype=jnp.int32)           # [nq, m]


def range_count(q: jax.Array, r: jax.Array, eps: float, metric: str = "cosine") -> jax.Array:
    """counts[i] = #-neighbors of q_i within eps. int32 [nq]."""
    return range_count_hist(q, r, jnp.asarray([eps]), metric)[:, 0]


def mlp_forward(params, x: jax.Array) -> jax.Array:
    """ReLU MLP regressor forward. params: list of (w [din,dout], b [1,dout]).

    Returns f32 [n] (last layer must have dout == 1). Oracle for
    kernels/fused_mlp.py.
    """
    h = x.astype(jnp.float32)
    for i, (w, b) in enumerate(params):
        h = h @ w.astype(jnp.float32) + b.astype(jnp.float32)
        if i < len(params) - 1:
            h = jnp.maximum(h, 0.0)
    return h[:, 0]
