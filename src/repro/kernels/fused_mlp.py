"""Pallas TPU kernel: fused MLP-regressor inference (the Xling estimator).

The estimator is evaluated for every query of every join — predictions are
the filter's fast path, so per-layer HBM round-trips matter. This kernel
pins ALL layer weights in VMEM (they are small: 4 hidden layers of width
<=512 over <=1024-dim inputs ~= 2-3 MB) and streams query blocks through the
whole network in one grid pass — one HBM read per input block, one write per
output block, zero intermediate traffic.

Weights use constant index_maps so every grid step sees the same VMEM-resident
blocks; rows are tiled with Bn=256 (8x the f32 sublane tile).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.range_count import default_interpret


def _make_kernel(n_layers: int):
    def kernel(x_ref, *refs):
        out_ref = refs[-1]
        wb = refs[:-1]
        h = x_ref[...].astype(jnp.float32)
        for li in range(n_layers):
            w = wb[2 * li][...].astype(jnp.float32)
            b = wb[2 * li + 1][...].astype(jnp.float32)
            h = jax.lax.dot_general(h, w, (((1,), (0,)), ((), ())),
                                    preferred_element_type=jnp.float32) + b
            if li < n_layers - 1:
                h = jnp.maximum(h, 0.0)
        out_ref[...] = h
    return kernel


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def mlp_forward_pallas(params, x: jax.Array, *, block_n: int = 256,
                       interpret: bool | None = None) -> jax.Array:
    """params: tuple of (w [din,dout], b [1,dout]) pairs, final dout == 1.
    x: [n, d0] with n % block_n == 0. Returns f32 [n].
    `interpret=None` derives the mode from the runtime platform
    (compiled on TPU, interpret elsewhere).
    """
    if interpret is None:
        interpret = default_interpret()
    n, d0 = x.shape
    assert n % block_n == 0
    n_layers = len(params)
    assert params[-1][0].shape[1] == 1

    flat = []
    in_specs = [pl.BlockSpec((block_n, d0), lambda i: (i, 0))]
    for w, b in params:
        flat += [w, b]
        in_specs += [
            pl.BlockSpec(w.shape, lambda i: (0, 0)),
            pl.BlockSpec(b.shape, lambda i: (0, 0)),
        ]

    out = pl.pallas_call(
        _make_kernel(n_layers),
        grid=(n // block_n,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_n, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, 1), jnp.float32),
        interpret=interpret,
    )(x, *flat)
    return out[:, 0]
