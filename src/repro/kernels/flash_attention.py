"""Pallas TPU kernel: fused flash attention (prefill/training forward).

Why this kernel exists (§Perf, minicpm3/llava prefill cells): the jnp-level
flash implementation materializes every S x chunk score/probability tile in
HBM — measured 240 s memory term on minicpm3_4b prefill_32k vs a 9 s compute
term. This kernel keeps the tiles in VMEM: per (batch x kv-head, q-block)
the online-softmax state (m, l, acc) lives in VMEM scratch and is revisited
across the kv-block grid dimension; HBM traffic drops to the linear
q/k/v/out streams.

TPU mapping:
  * grid = (B*K, n_q_blocks, n_kv_blocks), kv innermost — scratch persists
    across the kv sweep for one (bk, qi) cell (canonical TPU flash layout).
  * the score matmul is a single 2-D MXU dot: [Bq*G, Dk] x [Dk, c].
  * causal block skipping is REAL: fully-masked kv blocks are @pl.when'd
    out, so the 2x triangular waste of the XLA path disappears.
  * VMEM at defaults (Bq=64, c=256, G<=56, Dk<=128): k/v blocks ~128 KB,
    scores ~3.7 MB f32, acc <= 1.8 MB — comfortably under 16 MB.

The pure-jnp oracle is layers.chunked_attention / kernels.ref; tests sweep
shapes/dtypes in interpret mode (this container has no TPU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            causal: bool, block_q: int, block_kv: int, n_kv: int,
            kv_valid: int, scale: float):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * block_q
    k_start = kj * block_kv
    # causal block skip: this kv block only matters if its first key is not
    # after the last query of the block
    live = (k_start <= q_start + block_q - 1) if causal else True
    live = jnp.logical_and(live, k_start < kv_valid) if isinstance(live, jax.Array) \
        else (live and k_start < kv_valid)

    @pl.when(live if isinstance(live, jax.Array) else jnp.bool_(live))
    def _step():
        q = q_ref[0]                                   # [Bq, G, Dk]
        Bq, G, Dk = q.shape
        k = k_ref[0]                                   # [c, Dk]
        v = v_ref[0]                                   # [c, Dv]
        q2 = q.reshape(Bq * G, Dk)
        s = jax.lax.dot_general(q2, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        # masks: validity + causality (per query row, broadcast over G)
        key_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (Bq, G, k.shape[0]), 2)
        mask = key_pos < kv_valid
        if causal:
            q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (Bq, G, k.shape[0]), 0)
            mask = jnp.logical_and(mask, key_pos <= q_pos)
        mask = mask.reshape(Bq * G, k.shape[0])
        s = jnp.where(mask, s, _NEG)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
        acc_ref[...] = (acc_ref[...] * alpha[:, None]
                        + jax.lax.dot_general(p.astype(v.dtype), v,
                                              (((1,), (0,)), ((), ())),
                                              preferred_element_type=jnp.float32))
        m_ref[...] = m_new

    @pl.when(kj == n_kv - 1)
    def _finish():
        Bq, G = o_ref.shape[1], o_ref.shape[2]
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0] = out.reshape(Bq, G, -1).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_kv",
                                             "kv_valid", "interpret"))
def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, block_q: int = 64,
                           block_kv: int = 256, kv_valid: int = -1,
                           interpret: bool = True) -> jax.Array:
    """q [B,S,H,Dk], k [B,T,K,Dk], v [B,T,K,Dv]; H % K == 0; S % block_q == 0
    and T % block_kv == 0 (pad upstream; kv_valid masks the tail).
    Returns [B,S,H,Dv].
    """
    B, S, H, Dk = q.shape
    T, K = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    G = H // K
    assert S % block_q == 0 and T % block_kv == 0
    kv_valid = T if kv_valid < 0 else kv_valid
    scale = 1.0 / np.sqrt(Dk)

    # layout: fold kv-heads into the batch grid dim
    qg = (q.reshape(B, S, K, G, Dk).transpose(0, 2, 1, 3, 4)
          .reshape(B * K, S, G, Dk))
    kg = k.transpose(0, 2, 1, 3).reshape(B * K, T, Dk)
    vg = v.transpose(0, 2, 1, 3).reshape(B * K, T, Dv)

    n_q = S // block_q
    n_kv = T // block_kv
    kernel = functools.partial(_kernel, causal=causal, block_q=block_q,
                               block_kv=block_kv, n_kv=n_kv,
                               kv_valid=kv_valid, scale=scale)
    out = pl.pallas_call(
        kernel,
        grid=(B * K, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, block_q, G, Dk), lambda i, j, kk: (i, j, 0, 0)),
            pl.BlockSpec((1, block_kv, Dk), lambda i, j, kk: (i, kk, 0)),
            pl.BlockSpec((1, block_kv, Dv), lambda i, j, kk: (i, kk, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, G, Dv), lambda i, j, kk: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B * K, S, G, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q * G,), jnp.float32),      # m
            pltpu.VMEM((block_q * G,), jnp.float32),      # l
            pltpu.VMEM((block_q * G, Dv), jnp.float32),   # acc
        ],
        interpret=interpret,
    )(qg, kg, vg)
    return (out.reshape(B, K, S, G, Dv).transpose(0, 2, 1, 3, 4)
            .reshape(B, S, H, Dv))
