# Developer entry points (see DESIGN.md §8 for the lane definitions).
PYTEST := PYTHONPATH=src$(if $(PYTHONPATH),:$(PYTHONPATH)) python -m pytest

.PHONY: test fast lint docs-check guard ci serve example

test:        ## tier-1: the full suite (what the driver runs)
	$(PYTEST) -x -q

fast:        ## developer fast lane (< 90 s)
	$(PYTEST) -q -m "not slow"

lint:        ## xlint: static analysis of the DESIGN.md invariants (§12)
	python scripts/xlint

docs-check:  ## docs gate — alias for the xlint docstring-gate rule
	python scripts/xlint --rule docstring-gate

guard:       ## runtime transfer-guard lane only (tests/test_guards.py)
	$(PYTEST) -q -m guard

ci:          ## hygiene + lint gate + fast lane, one entry point
	bash scripts/ci.sh

serve:       ## smoke-run the async serving driver
	PYTHONPATH=src python -m repro.launch.serve --n 3000 --batches 3 \
	    --batch-size 128 --epochs 5 --verify lsh --depth 2

example:     ## the worked streaming example (DESIGN.md §5)
	python examples/stream_lsh_verify.py
